"""Event-engine scale gate: 10^6 requests over a 100-replica mixed fleet.

PR-6's event heap made a 16-replica / 10^5-request replay tractable; this
benchmark is the acceptance gate for the next order of magnitude, where
the per-request cost must be O(event-loop bookkeeping), not O(jit
dispatch). The levers under test (``repro.serving.events`` +
``repro.serving.pool``):

    fused admission prefill   same-instant admission ticks defer their
                              ``_jit_prefill`` dispatches; the engine runs
                              one grouped program per (config, params,
                              bucket) and replays per-request accounting
                              byte-identically
    fusion quantum            decode events inside ``[t, t+q)`` share one
                              dispatch even when replica clocks have
                              drifted off exact ties
    pow2 group bucketing      fused program cache stays O(log fleet) on a
                              drifting fleet instead of one trace per
                              group size
    batched replica axis      a fused group of K pools runs as ONE
                              vmap/shard_map-batched program over
                              replica-stacked cache banks instead of a
                              tuple of K traced sub-calls
                              (``batch_replicas``; ``--batched=off``
                              replays the tuple baseline)
    allocation-free loops     request/ledger freelists + ``on_finish``
                              streaming keep the replay memory-flat;
                              round-robin routing is O(1) per arrival

Fleet: 88 gemma-class + 12 minicpm-class replicas (heterogeneous groups
fuse within themselves). Trace: an aligned phase (waves of one request
per replica at one-step cadence — the fused fast path's shape) followed
by a drifted phase (mixed prompt lengths, jittered arrivals — the shape
only the quantum window and pow2 bucketing keep fused).

Asserted:

    scale       all requests complete; double replay streams to the SAME
                sha256 (outputs + ledger stamps + measured joules)
    aligned     >= 80% of decode pool-steps ran through fused dispatches
                on the aligned phase
    dispatch    jit dispatches/request with full fusion strictly below
                the PR-6 dispatch pattern (serial admission prefill,
                exact-tie-only decode fusion) on the same trace, and
                under an absolute ceiling
    quantum     ``fusion_quantum_s=0`` replays byte-identical to the
                exact-tie engine; a positive quantum changes no token
    batched     the vmap-batched fused dispatch streams to the SAME
                sha256 as the tuple-of-K program, and its measured wall
                per fused call beats the tuple's at group sizes >= 8
                (the dispatch-vs-group-size curve lands in the JSON)
    wall        slowest full replay fits the budget
                (REPRO_SCALE_TIME_BUDGET_S, default 3600 s; 0 waives)

Run:  PYTHONPATH=src python -m benchmarks.serve_scale            # full
  or: PYTHONPATH=src python -m benchmarks.serve_scale --smoke    # CI tier
  add --json to write BENCH_serve_scale.json (schema-versioned artefact)
  add --batched=off to replay the tuple-of-K baseline (artefact goes to
  BENCH_serve_scale_unbatched.json so both modes can be diffed)
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
import time

import jax
import numpy as np

from benchmarks.common import h200_model, write_bench_json, write_csv
from repro.configs import reduced_config
from repro.core.traces import TracedRequest
from repro.models import init_params
from repro.serving import (
    ClockSpec,
    Fleet,
    FleetSpec,
    PoolSpec,
    ReplicaSpec,
    clear_program_caches,
)
from repro.serving.pool import release_request

ARCH_MAIN = "gemma-2b"
ARCH_ALT = "minicpm-2b"
N_MAIN = 88
N_ALT = 12
N_REPLICAS = N_MAIN + N_ALT
BATCH = 8
MAX_SEQ_LEN = 64
CHUNK_TOKENS = 64
PROMPT_LEN = 16
MAX_NEW = 4
WAVE_DT_S = 0.0021                  # ~ one locked-clock decode step
QUANTUM_S = 0.0005                  # ~ a quarter step: re-fuses drift
TRACE_SEED = 23
DISPATCH_CEILING = 1.5              # jit dispatches per request, full run
JSON_PATH = "BENCH_serve_scale.json"
UNBATCHED_JSON_PATH = "BENCH_serve_scale_unbatched.json"
# wall-clock budget for ONE full replay; 0 waives
TIME_BUDGET_S = float(os.environ.get("REPRO_SCALE_TIME_BUDGET_S", "3600"))

_PARAMS_CACHE = {}


def params_for():
    for arch in (ARCH_MAIN, ARCH_ALT):
        if arch not in _PARAMS_CACHE:
            _PARAMS_CACHE[arch] = init_params(
                reduced_config(arch), jax.random.PRNGKey(0))
    return _PARAMS_CACHE


def make_fleet() -> Fleet:
    archs = [ARCH_MAIN] * N_MAIN + [ARCH_ALT] * N_ALT
    spec = FleetSpec(
        replicas=tuple(
            ReplicaSpec(name=f"r{i:03d}", arch=arch,
                        clock=ClockSpec(mode="lock"),
                        decode=PoolSpec(batch=BATCH),
                        max_seq_len=MAX_SEQ_LEN,
                        prefill_chunk_tokens=CHUNK_TOKENS)
            for i, arch in enumerate(archs)),
        router="rr",                # O(1) per arrival; JSQ would be O(N)
    )
    return Fleet.from_spec(spec, emodel=h200_model(), params_for=params_for())


def aligned_trace(n_requests: int, *, t0: float = 0.0):
    """Waves of one identical prompt per replica at one-step cadence —
    every fused path (admission + decode) at full coverage. The prompt
    array is SHARED across requests: a million-request trace must not
    hold a million numpy buffers. Partial waves are dropped; callers
    surface the count (see serve_events.wave_trace)."""
    rng = np.random.default_rng(TRACE_SEED)
    prompt = rng.integers(1, 100, PROMPT_LEN).astype(np.int32)
    n_waves = n_requests // N_REPLICAS
    trace = [
        TracedRequest(arrival_s=t0 + w * WAVE_DT_S, prompt=prompt,
                      max_new_tokens=MAX_NEW, bucket="mixed")
        for w in range(n_waves) for _ in range(N_REPLICAS)
    ]
    return trace, n_requests - len(trace)


def drifted_trace(n_requests: int, *, t0: float = 0.0):
    """Mixed prompt lengths + jittered arrivals: replica clocks drift off
    exact ties, so only the fusion quantum and pow2 group bucketing keep
    dispatches shared. Prompts come from a small shared pool of arrays."""
    rng = np.random.default_rng(TRACE_SEED + 1)
    pool = [rng.integers(1, 100, int(n)).astype(np.int32)
            for n in rng.integers(8, 25, 32)]
    trace = []
    for i in range(n_requests):
        jitter = float(rng.uniform(0.0, 0.3 * WAVE_DT_S))
        trace.append(TracedRequest(
            arrival_s=t0 + (i // N_REPLICAS) * WAVE_DT_S + jitter,
            prompt=pool[int(rng.integers(0, len(pool)))],
            max_new_tokens=MAX_NEW, bucket="mixed"))
    return trace


def scale_trace(n_requests: int):
    """Aligned phase then drifted phase, half each."""
    n_aligned = n_requests // 2
    a, dropped = aligned_trace(n_aligned)
    t0 = (len(a) // N_REPLICAS + 2) * WAVE_DT_S if a else 0.0
    d = drifted_trace(n_requests - len(a), t0=t0)
    return a + d, dropped


class StreamHash:
    """Streaming replay fingerprint + latency accumulator: hashes every
    finished request in completion order and releases it back to the
    request freelist, so the replay holds O(in-flight) requests."""

    def __init__(self):
        self._h = hashlib.sha256()
        self.completed = 0
        self.ttft = []
        self.e2e = []

    def __call__(self, req):
        led = req.ledger
        self._h.update(json.dumps(
            [req.replica, req.uid, req.output, led.arrival_s,
             led.admitted_s, led.first_token_s, led.finish_s]).encode())
        self.completed += 1
        self.ttft.append(led.first_token_s - led.arrival_s)
        self.e2e.append(led.finish_s - led.arrival_s)
        release_request(req)

    def digest(self, fleet) -> str:
        self._h.update(json.dumps(fleet.measured_energy_j(),
                                  sort_keys=True).encode())
        return self._h.hexdigest()


def replay(trace, **engine_opts):
    """One streamed replay; returns (metrics, sha256, wall_s)."""
    fleet = make_fleet()
    stream = StreamHash()
    opts = {"on_finish": stream, **engine_opts}
    t0 = time.perf_counter()
    fleet.run_trace(trace, max_steps=1_000_000_000, engine_opts=opts)
    wall_s = time.perf_counter() - t0
    st = fleet.last_engine_stats
    ttft = np.asarray(stream.ttft)
    metrics = {
        "completed": stream.completed,
        "requests": len(trace),
        "replicas": N_REPLICAS,
        "decode_steps": st.decode_steps,
        "jit_dispatches": st.jit_dispatches,
        "dispatches_per_request": st.jit_dispatches / max(len(trace), 1),
        "fused_decode_coverage": st.fused_decode_coverage,
        "fused_prefill_coverage": st.fused_prefill_coverage,
        "batched_decode_calls": st.batched_decode_calls,
        "bank_rebuilds": st.bank_rebuilds,
        "peak_heap": st.peak_heap,
        "events": st.events,
        "total_j": fleet.total_energy_j(),
        "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else None,
        "p99_ttft_s": float(np.percentile(ttft, 99)) if len(ttft) else None,
        "engine_stats": st.as_dict(),
    }
    return metrics, stream.digest(fleet), wall_s


def dispatch_curve(smoke: bool):
    """Measured wall seconds inside fused decode dispatches vs group size,
    batched vs tuple program, on the aligned trace (full fused coverage).
    ``clear_program_caches()`` between points so every point pays its own
    compiles — the curve is (compile + dispatch) per fused call, the cost a
    replay actually sees the first time it meets a group size."""
    sweep = (4, 8, 32) if smoke else (4, 8, 16, 32, 64)
    n = 1_500 if smoke else 20_000
    trace, _ = aligned_trace(n)
    curve: dict = {}
    for g in sweep:
        for mode, flag in (("batched", True), ("tuple", False)):
            clear_program_caches()
            fleet = make_fleet()
            fleet.run_trace(trace, max_steps=1_000_000_000, engine_opts={
                "fusion_quantum_s": QUANTUM_S, "max_fused_group": g,
                "batch_replicas": flag, "time_dispatch": True})
            st = fleet.last_engine_stats
            calls = sum(int(v[0]) for v in st.fused_decode_wall.values())
            secs = sum(v[1] for v in st.fused_decode_wall.values())
            curve.setdefault(str(g), {})[mode] = {
                "fused_calls": calls,
                "dispatch_wall_s": secs,
                "us_per_fused_call": 1e6 * secs / max(calls, 1),
                "by_size": st.fused_decode_wall,
            }
    clear_program_caches()
    return curve


def run(smoke: bool = False, write_json: bool = False, batched: bool = True):
    """Harness contract: yields (name, us_per_call, derived) rows; raises
    on any violated completion/determinism/coverage/dispatch assertion."""
    if smoke:
        n_scale, n_aligned, n_compare = 4_000, 2_000, 1_000
    else:
        n_scale, n_aligned, n_compare = 1_000_000, 50_000, 10_000
    # every replay below runs in the requested engine mode; the batched
    # identity section crosses over to the OTHER mode to pin the sha
    base = {"batch_replicas": batched}

    out_rows = []
    violations = []

    # ---- the scale run: mixed trace, streamed, double replay -------------
    trace, dropped = scale_trace(n_scale)
    if dropped:
        print(f"serve_scale: dropped {dropped} requests to whole waves",
              file=sys.stderr)
    first, sha_a, wall_a = replay(trace, fusion_quantum_s=QUANTUM_S, **base)
    again, sha_b, wall_b = replay(trace, fusion_quantum_s=QUANTUM_S, **base)
    out_rows.append((
        "serve_scale/replay",
        1e6 * wall_a / max(len(trace), 1),
        f"requests={len(trace)};dropped={dropped};replicas={N_REPLICAS};"
        f"dispatches_per_request={first['dispatches_per_request']:.3f};"
        f"peak_heap={first['peak_heap']};total_j={first['total_j']:.1f};"
        f"wall_s={wall_a:.1f}",
    ))
    if first["completed"] != len(trace):
        violations.append(
            f"scale: {first['completed']}/{len(trace)} completed")
    identical = sha_a == sha_b and first == again
    if not identical:
        violations.append("scale replay NOT byte-identical across runs")
    out_rows.append((
        "serve_scale/determinism", 0.0,
        f"byte_identical={identical};sha={sha_a[:16]}",
    ))
    # prefix sharing defaults off: this fleet must be untouched by it
    es = first["engine_stats"]
    if (es["prefix_hits"], es["prefix_cow_splits"],
            es["saved_prefill_j"]) != (0, 0, 0.0):
        violations.append(
            f"prefix sharing leaked into a sharing-off fleet: "
            f"hits={es['prefix_hits']} cow={es['prefix_cow_splits']} "
            f"saved_j={es['saved_prefill_j']}")
    if first["dispatches_per_request"] >= DISPATCH_CEILING:
        violations.append(
            f"{first['dispatches_per_request']:.3f} jit dispatches/request "
            f"(ceiling {DISPATCH_CEILING})")

    # ---- aligned phase: fused coverage ------------------------------------
    atrace, _ = aligned_trace(n_aligned)
    amet, _, _ = replay(atrace, **base)
    if amet["fused_decode_coverage"] < 0.80:
        violations.append(
            f"aligned fused decode coverage "
            f"{100 * amet['fused_decode_coverage']:.1f}% < 80%")
    out_rows.append((
        "serve_scale/aligned_coverage", 0.0,
        f"fused_decode_pct={100 * amet['fused_decode_coverage']:.1f};"
        f"fused_prefill_pct={100 * amet['fused_prefill_coverage']:.1f}",
    ))

    # ---- dispatch count: full fusion vs the PR-6 dispatch pattern ---------
    ctrace, _ = scale_trace(n_compare)
    fused_m, fused_sha, _ = replay(ctrace, fusion_quantum_s=QUANTUM_S, **base)
    serial_m, _, _ = replay(ctrace, fuse_prefill=False, **base)
    if not fused_m["jit_dispatches"] < serial_m["jit_dispatches"]:
        violations.append(
            f"fusion did not reduce dispatches: "
            f"{fused_m['jit_dispatches']} vs {serial_m['jit_dispatches']}")
    out_rows.append((
        "serve_scale/dispatches_vs_serial", 0.0,
        f"fused={fused_m['jit_dispatches']};"
        f"serial={serial_m['jit_dispatches']};"
        f"saved_pct={100 * (1 - fused_m['jit_dispatches'] / max(serial_m['jit_dispatches'], 1)):.1f}",
    ))

    # ---- batched replica axis: cross-mode byte identity -------------------
    # the tentpole gate: ONE vmap-batched program over replica-stacked
    # cache banks streams to the SAME sha256 as the tuple of K traced
    # sub-calls on the same trace
    cross_m, cross_sha, _ = replay(ctrace, fusion_quantum_s=QUANTUM_S,
                                   batch_replicas=not batched)
    if cross_sha != fused_sha:
        violations.append(
            "batched fused dispatch NOT byte-identical to the tuple-of-K "
            "program")
    bat_m = fused_m if batched else cross_m
    if bat_m["batched_decode_calls"] == 0:
        violations.append("batched replica axis was never exercised")
    out_rows.append((
        "serve_scale/batched_identity", 0.0,
        f"byte_identical={cross_sha == fused_sha};"
        f"batched_decode_calls={bat_m['batched_decode_calls']};"
        f"bank_rebuilds={bat_m['bank_rebuilds']}",
    ))

    # ---- quantum semantics ------------------------------------------------
    q0_m, q0_sha, _ = replay(ctrace, fusion_quantum_s=0.0, **base)
    exact_m, exact_sha, _ = replay(ctrace, **base)
    if q0_sha != exact_sha:
        violations.append("quantum=0 NOT byte-identical to exact-tie engine")
    if fused_sha != q0_sha:
        # the quantum only regroups dispatches: outputs/stamps/joules are
        # invariant, so even the positive-quantum replay matches
        violations.append("positive quantum changed the replay fingerprint")
    out_rows.append((
        "serve_scale/quantum", 0.0,
        f"q0_identical={q0_sha == exact_sha};"
        f"q_invariant={fused_sha == q0_sha};quantum_s={QUANTUM_S}",
    ))

    # ---- dispatch wall vs group size: batched must win at >= 8 ------------
    # only in the primary (batched) invocation: the curve already measures
    # BOTH modes per point, so the opt-out artefact need not repeat it
    curve = {}
    if batched:
        curve = dispatch_curve(smoke)
        for g, point in sorted(curve.items(), key=lambda kv: int(kv[0])):
            b = point["batched"]["us_per_fused_call"]
            t = point["tuple"]["us_per_fused_call"]
            if int(g) >= 8 and not b < t:
                violations.append(
                    f"batched dispatch slower at group size {g}: "
                    f"{b:.0f}us vs tuple {t:.0f}us per fused call")
            out_rows.append((
                f"serve_scale/dispatch_curve/g{g}", b,
                f"batched_us_per_call={b:.0f};tuple_us_per_call={t:.0f};"
                f"speedup={t / max(b, 1e-9):.2f}x;"
                f"calls={point['batched']['fused_calls']}",
            ))

    # ---- wall budget ------------------------------------------------------
    slowest = max(wall_a, wall_b)
    if TIME_BUDGET_S > 0:
        if slowest > TIME_BUDGET_S:
            violations.append(
                f"a replay took {slowest:.1f}s "
                f"(> {TIME_BUDGET_S:.0f}s budget)")
        out_rows.append((
            "serve_scale/wall_time", 0.0,
            f"slowest_replay_s={slowest:.1f};budget_s={TIME_BUDGET_S:.0f}",
        ))

    results = {"scale": first, "scale_sha": sha_a, "aligned": amet,
               "dispatch": {"fused": fused_m["jit_dispatches"],
                            "serial": serial_m["jit_dispatches"]},
               "batched": {"mode": "batched" if batched else "tuple",
                           "cross_mode_identical": cross_sha == fused_sha,
                           "batched_decode_calls":
                               bat_m["batched_decode_calls"],
                           "bank_rebuilds": bat_m["bank_rebuilds"]},
               "dispatch_curve": curve,
               "wall_s": [wall_a, wall_b]}
    write_csv("serve_scale", ["metric", "value"],
              [[k, v] for k, v in first.items() if k != "engine_stats"]
              + [["aligned_fused_decode_coverage",
                  amet["fused_decode_coverage"]],
                 ["dispatch_fused", fused_m["jit_dispatches"]],
                 ["dispatch_serial", serial_m["jit_dispatches"]]])
    if write_json:
        json_path = JSON_PATH if batched else UNBATCHED_JSON_PATH
        write_bench_json(
            "serve_scale", results, smoke=smoke, path=json_path,
            trace={"n": len(trace), "n_requested": n_scale,
                   "dropped": dropped, "shape": "aligned+drifted",
                   "wave_dt_s": WAVE_DT_S, "quantum_s": QUANTUM_S,
                   "prompt_len": PROMPT_LEN, "max_new": MAX_NEW,
                   "seed": TRACE_SEED, "batched": batched},
        )
        out_rows.append(("serve_scale/json", 0.0, f"wrote={json_path}"))
    if violations:
        raise RuntimeError("; ".join(violations))
    return out_rows


def main():
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    write_json = "--json" in argv
    batched = True
    for a in argv:
        if a.startswith("--batched"):
            val = a.partition("=")[2] or "on"
            if val not in ("on", "off"):
                print(f"--batched takes on|off, got {val!r}")
                sys.exit(2)
            batched = val == "on"
    ok = True
    try:
        for name, us, derived in run(smoke=smoke, write_json=write_json,
                                     batched=batched):
            print(f"{name},{us:.1f},{derived}")
    except RuntimeError as e:
        print(f"serve_scale checks VIOLATED: {e}")
        ok = False
    print("serve_scale checks:", "OK" if ok else "VIOLATED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
