"""Fleet replay: three routing policies over a heterogeneous 3-replica
fleet, under static-lock and closed-loop SLO clocking.

The paper's per-arch DVFS table as a *fleet scheduling signal*: a seeded
diurnal arrival trace (mixed short-chat / long-context lengths, a day
compressed to minutes) is replayed in virtual time over three replicas of
DIFFERENT architectures — GQA (qwen3-4b), MLA (minitron-4b-mla), GDN
(gdn-4b) — behind each of the pluggable routers:

    jsq       join-shortest-queue (the load-balancing baseline)
    energy    marginal-joules/token placement (consolidates load: batching
              amortises weight streaming, idle replicas sit at the floor)
    affinity  length-bucketed arch dispatch (long-context -> the arch with
              the flattest energy curve, i.e. GDN's O(1) state)

Each replica holds its own ClockController (mode lock or slo, walked per
replica); all share one virtual timeline. ``context_scale`` prices each
live trace token as 256 production tokens, so the miniature replay
exercises the full configs' long-context energy regimes.

Asserted, per clock mode:

    energy-aware routing spends <= the joules of join-shortest-queue at
        equal-or-better p99 TBT                    (placement is an energy lever)
    the heterogeneous fleet under arch-affinity beats a homogeneous
        all-GQA fleet on total joules              (heterogeneity pays)
    the replay is byte-identical across runs and each completes in < 60 s

Also reported (the ROADMAP's power-down question): the same trace with one
replica drained+powered-down from the start vs. underclocking all three.

Run:  PYTHONPATH=src python -m benchmarks.serve_fleet            # full
  or: PYTHONPATH=src python -m benchmarks.serve_fleet --smoke    # CI tier
  add --json to write BENCH_serve_fleet.json (the perf-record artefact)
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax

from benchmarks.common import h200_model, write_bench_json, write_csv
from repro.configs import get_config, reduced_config
from repro.core import decode_workload, generate_trace, prefill_workload
from repro.core.latency import summarize_latency
from repro.models import init_params
from repro.serving import ClockSpec, Fleet, FleetSpec, PoolSpec, ReplicaSpec

HET_ARCHS = ("qwen3-4b", "minitron-4b-mla", "gdn-4b")     # GQA / MLA / GDN
HOMO_ARCHS = ("qwen3-4b",) * 3                            # the GQA monoculture
ROUTERS = ("jsq", "energy", "affinity")
MODES = ("lock", "slo")

BATCH = 8
MAX_SEQ_LEN = 128
KV_BLOCK_SIZE = 8
KV_BLOCKS = 128                     # dense-equivalent budget: no preemption churn
CHUNK_TOKENS = 64
CONTEXT_SCALE = 256.0               # 1 trace token ~ 256 production tokens
MIX_LONG = 0.5                      # long-context fraction of the mixed profile
MEAN_NEW = 12.5                     # mixed-profile mean decode budget
UTILISATION = 0.75                  # mean arrival rate vs serialised capacity
TRACE_SEED = 23
JSON_PATH = "BENCH_serve_fleet.json"
# wall-clock budget for one full replay (the acceptance bar); 0 waives
TIME_BUDGET_S = float(os.environ.get("REPRO_FLEET_TIME_BUDGET_S", "60"))


def fleet_targets(emodel, archs):
    """Model-derived SLO targets + matching diurnal arrival rate. Replicas
    tick concurrently (one round costs the slowest busy replica), so the
    worst TBT is the slowest arch's decode step plus its chunked-prefill
    interleave — target twice that. Fleet capacity is the SUM of per-replica
    decode throughputs, and the rate is set well above what one replica can
    hold: routing across replicas is load-bearing, not cosmetic —
    consolidating the whole trace onto one replica is not a feasible
    answer."""
    f_floor = min(emodel.clock_grid())
    ctx_rep = int(60 * CONTEXT_SCALE)       # mean live context, scaled
    throughput = 0.0
    t_worst = 0.0
    for arch in archs:
        full = get_config(arch)
        t_dec = emodel.profile(
            decode_workload(full, BATCH, ctx_rep, fused=True), f_floor).t_total
        wp = prefill_workload(full, 1, 4096, fused=True)
        prof_p = emodel.profile(wp, emodel.spec.f_max)
        t_chunk = prof_p.t_total / prof_p.tokens * CHUNK_TOKENS
        throughput += BATCH / t_dec
        t_worst = max(t_worst, t_dec + t_chunk)
    # 3x: a fleet round is the slowest replica's tick, and a tick can carry
    # several chunked admissions at a diurnal peak
    tbt_s = 3.0 * t_worst
    ttft_s = 100.0 * tbt_s
    capacity_rps = throughput / MEAN_NEW
    return tbt_s, ttft_s, UTILISATION * capacity_rps


def fleet_spec(archs, router: str, mode: str, tbt_s: float, ttft_s: float) -> FleetSpec:
    replicas = tuple(
        ReplicaSpec(
            name=f"r{i}-{arch}",
            arch=arch,
            clock=ClockSpec(mode=mode, context_scale=CONTEXT_SCALE,
                            fused=True,     # the pools run the fused Pallas
                                            # kernels; price workloads there
                            slo_tbt_s=tbt_s, slo_ttft_s=ttft_s),
            decode=PoolSpec(batch=BATCH, paged=True,
                            kv_block_size=KV_BLOCK_SIZE, kv_blocks=KV_BLOCKS),
            max_seq_len=MAX_SEQ_LEN,
            prefill_chunk_tokens=CHUNK_TOKENS,
        )
        for i, arch in enumerate(archs)
    )
    # energy: spill a little before the batch fills — the last slots of a
    # packed replica buy less amortisation than they cost in queueing
    router_args = {"energy": {"headroom": 0.75}}.get(router, {})
    return FleetSpec(replicas=replicas, router=router, router_args=router_args)


_PARAMS_CACHE = {}


def params_for(archs):
    """Init each arch's reduced params once per process; replica builds and
    repeated runs share them (they are read-only on the serving path)."""
    for arch in set(archs):
        if arch not in _PARAMS_CACHE:
            _PARAMS_CACHE[arch] = init_params(
                reduced_config(arch), jax.random.PRNGKey(0))
    return _PARAMS_CACHE


def make_trace(n_requests: int, rate_rps: float):
    # generated against the GQA config (all three reduced vocabs match, and
    # lengths are arch-agnostic); two diurnal periods span the trace so the
    # replay sees both a peak and a valley
    period_s = max(1.0, n_requests / rate_rps / 2.0)
    return generate_trace(
        reduced_config(HET_ARCHS[0]), n_requests, arrival="diurnal",
        lengths="mixed", mix_long=MIX_LONG, seed=TRACE_SEED,
        max_total_len=MAX_SEQ_LEN,
        rate_rps=rate_rps, arrival_kwargs={"period_s": period_s},
    )


def replay(archs, router: str, mode: str, trace, tbt_s, ttft_s, *,
           drain: str = ""):
    """One virtual-time fleet replay; returns (deterministic metrics, wall s)."""
    spec = fleet_spec(archs, router, mode, tbt_s, ttft_s)
    # clock=None: one VirtualClock per replica — devices tick concurrently,
    # barrier-synced each round
    fleet = Fleet.from_spec(spec, emodel=h200_model(),
                            params_for=params_for(archs))
    if drain:
        fleet.drain(drain)
    t0 = time.perf_counter()
    done = fleet.run_trace(trace)
    wall_s = time.perf_counter() - t0
    lat = summarize_latency(done)
    stats = fleet.stats
    measured = fleet.measured_energy_j()
    by_replica = {}
    for r in fleet.replicas:
        served = [q for q in done if q.replica == r.name]
        by_replica[r.name] = {
            "arch": r.arch,
            "completed": len(served),
            "long_served": sum(q.bucket == "long" for q in served),
            "short_served": sum(q.bucket == "short" for q in served),
            "decode_tokens": r.decode_stats.decode_tokens,
            "decode_j": r.decode_stats.decode_j,
            "measured_j": sum(measured[r.name].values()),
            "decode_clock_mhz": r.decode_stats.actual_clock_mhz,
            "peak_occupancy": r.decode_pool.peak_occupancy,
            "powered": r.powered,
        }
    return {
        "routing": router,
        "mode": mode,
        "archs": list(archs),
        "drained": drain,
        "completed": len(done),
        "requests": len(trace),
        "decode_tokens": stats.decode_tokens,
        "decode_j": stats.decode_j,
        "total_j": fleet.total_energy_j(),
        "j_per_decode_token": stats.decode_j / max(stats.decode_tokens, 1),
        "p50_ttft_s": lat.p50_ttft_s,
        "p99_ttft_s": lat.p99_ttft_s,
        "p50_tbt_s": lat.p50_tbt_s,
        "p99_tbt_s": lat.p99_tbt_s,
        "p99_queue_s": lat.p99_queue_s,
        "p99_e2e_s": lat.p99_e2e_s,
        "slo_met": lat.meets(ttft_s=ttft_s, tbt_s=tbt_s),
        "preemptions": sum(r.preemptions for r in done),
        "replicas": by_replica,
        "tbt_target_s": tbt_s,
        "ttft_target_s": ttft_s,
    }, wall_s


def run(smoke: bool = False, write_json: bool = False):
    """Harness contract: yields (name, us_per_call, derived) rows; raises on
    any violated routing/energy/determinism assertion."""
    n_requests = 120 if smoke else 240
    emodel = h200_model()
    tbt_s, ttft_s, rate_rps = fleet_targets(emodel, HET_ARCHS)
    trace = make_trace(n_requests, rate_rps)
    results = {}
    out_rows = []
    violations = []
    wall_by_run = {}

    def one(key, archs, router, mode, **kw):
        r, wall_s = replay(archs, router, mode, trace, tbt_s, ttft_s, **kw)
        results[key] = r
        wall_by_run[key] = wall_s
        out_rows.append((
            f"serve_fleet/{key}",
            1e6 * r["j_per_decode_token"],        # uJ per decode token
            f"total_j={r['total_j']:.3f};"
            f"p99_tbt_ms={1e3 * r['p99_tbt_s']:.2f};"
            f"p99_queue_ms={1e3 * r['p99_queue_s']:.2f};"
            f"slo_met={r['slo_met']};"
            f"long_to={max(r['replicas'], key=lambda n: r['replicas'][n]['long_served'])}",
        ))
        if r["completed"] != n_requests:
            violations.append(f"{key}: {r['completed']}/{n_requests} completed")
        return r

    for mode in MODES:
        for router in ROUTERS:
            one(f"het/{router}/{mode}", HET_ARCHS, router, mode)
        # ---- placement as an energy lever, asserted ----------------------
        jsq, ea = results[f"het/jsq/{mode}"], results[f"het/energy/{mode}"]
        if ea["total_j"] > jsq["total_j"] * (1 + 1e-9):
            violations.append(
                f"{mode}: energy-aware routing spent {ea['total_j']:.3f}J "
                f"> jsq's {jsq['total_j']:.3f}J")
        # "equal-or-better": a fleet round is ~one decode step (>= 10 ms
        # here), so differences under a tenth of a round are below the
        # timeline's resolution — treat them as equal
        if ea["p99_tbt_s"] > jsq["p99_tbt_s"] * 1.10:
            violations.append(
                f"{mode}: energy-aware p99 TBT {ea['p99_tbt_s']:.4f}s worse "
                f"than jsq's {jsq['p99_tbt_s']:.4f}s beyond round jitter")
        out_rows.append((
            f"serve_fleet/energy_vs_jsq/{mode}", 0.0,
            f"saved_pct={100 * (1 - ea['total_j'] / jsq['total_j']):.2f};"
            f"jsq_p99_tbt_ms={1e3 * jsq['p99_tbt_s']:.2f};"
            f"ea_p99_tbt_ms={1e3 * ea['p99_tbt_s']:.2f}",
        ))

    # ---- heterogeneity pays: affinity fleet vs the GQA monoculture -------
    homo = one("homo-gqa/affinity/lock", HOMO_ARCHS, "affinity", "lock")
    het = results["het/affinity/lock"]
    if het["total_j"] >= homo["total_j"]:
        violations.append(
            f"heterogeneous affinity fleet spent {het['total_j']:.3f}J, not "
            f"below the homogeneous-GQA fleet's {homo['total_j']:.3f}J")
    out_rows.append((
        "serve_fleet/het_vs_homo", 0.0,
        f"het_j={het['total_j']:.3f};homo_j={homo['total_j']:.3f};"
        f"saved_pct={100 * (1 - het['total_j'] / homo['total_j']):.2f}",
    ))

    # ---- the ROADMAP question, reported: power down vs underclock all ----
    drained = one("het/jsq/lock/drain1", HET_ARCHS, "jsq", "lock",
                  drain=f"r1-{HET_ARCHS[1]}")
    all3 = results["het/jsq/lock"]
    out_rows.append((
        "serve_fleet/power_down_vs_underclock", 0.0,
        f"all3_j={all3['total_j']:.3f};drain1_j={drained['total_j']:.3f};"
        f"drain_saves_pct={100 * (1 - drained['total_j'] / all3['total_j']):.2f};"
        f"drain_p99_tbt_ms={1e3 * drained['p99_tbt_s']:.2f};"
        f"all3_p99_tbt_ms={1e3 * all3['p99_tbt_s']:.2f}",
    ))
    if not drained["replicas"][f"r1-{HET_ARCHS[1]}"]["powered"]:
        pass    # expected: the drained replica parked at zero watts
    else:
        violations.append("drained replica never powered down")
    if drained["replicas"][f"r1-{HET_ARCHS[1]}"]["measured_j"] > 0.0:
        violations.append("drained replica accrued joules while parked")

    # ---- determinism: a second replay must be byte-identical -------------
    again, wall_again = replay(HET_ARCHS, "energy", "slo", trace, tbt_s, ttft_s)
    blob_a = json.dumps(results["het/energy/slo"], sort_keys=True)
    blob_b = json.dumps(again, sort_keys=True)
    if blob_a != blob_b:
        violations.append("het/energy/slo: replay NOT deterministic")
    out_rows.append((
        "serve_fleet/determinism", 0.0,
        f"byte_identical={blob_a == blob_b};requests={n_requests}",
    ))
    if not smoke and TIME_BUDGET_S > 0:
        slowest = max(wall_by_run.values())
        if slowest > TIME_BUDGET_S:
            violations.append(
                f"a {n_requests}-request fleet replay took {slowest:.1f}s "
                f"(> {TIME_BUDGET_S:.0f}s budget)")
        out_rows.append((
            "serve_fleet/wall_time", 0.0,
            f"slowest_replay_s={slowest:.1f};budget_s={TIME_BUDGET_S:.0f}",
        ))

    flat_keys = [k for k in next(iter(results.values())) if k != "replicas"]
    write_csv("serve_fleet", ["run"] + flat_keys,
              [[k] + [r[f] for f in flat_keys] for k, r in results.items()])
    if write_json:
        write_bench_json(
            "serve_fleet", results, smoke=smoke, path=JSON_PATH,
            trace={"n": n_requests, "arrival": "diurnal", "lengths": "mixed",
                   "mix_long": MIX_LONG, "seed": TRACE_SEED,
                   "rate_rps": rate_rps},
        )
        out_rows.append(("serve_fleet/json", 0.0, f"wrote={JSON_PATH}"))
    if violations:
        raise RuntimeError("; ".join(violations))
    return out_rows


def main():
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    write_json = "--json" in argv
    ok = True
    try:
        for name, us, derived in run(smoke=smoke, write_json=write_json):
            print(f"{name},{us:.1f},{derived}")
    except RuntimeError as e:
        print(f"serve_fleet checks VIOLATED: {e}")
        ok = False
    print("serve_fleet checks:", "OK" if ok else "VIOLATED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
