"""§6.4 deployable policy table: per-arch DVFS class + static clocks, for
both the paper's models (H200) and the 10 assigned archs (TPU v5e)."""
from __future__ import annotations

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.paper_models import PARADIGM
from repro.core import policy_table

from benchmarks.common import Row, h200_model, paper_models, timed, v5e_model, write_csv


def run() -> list[Row]:
    rows_all = []

    def build():
        out = []
        h200 = h200_model()
        for r in policy_table(h200, paper_models()):
            out.append(["h200"] + list(r.as_dict().values()))
        v5e = v5e_model()
        assigned = {a: get_config(a) for a in ASSIGNED_ARCHS}
        for r in policy_table(v5e, assigned):
            out.append(["tpu-v5e"] + list(r.as_dict().values()))
        return out

    rows, us = timed(build)
    write_csv(
        "policy_table",
        ["chip", "arch", "dvfs_class", "decode_clock_bs1", "decode_clock_bs32",
         "decode_clock_bs32_long", "prefill_clock", "est_savings_w"],
        rows,
    )
    classes = {}
    for r in rows:
        classes[r[2]] = classes.get(r[2], 0) + 1
    derived = ";".join(f"{k}={v}" for k, v in sorted(classes.items()))
    return [("policy_table", us, derived)]
