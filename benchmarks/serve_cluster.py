"""Cluster-level lever comparison: default vs power-cap vs per-pool lock,
served over the PAGED decode pool at production-style batch sizes.

Reproduces the paper's §7.1 deployment claim end to end on the real
disaggregated serving stack: two architectures from different DVFS classes
are served through the prefill/decode cluster under three controller modes,
and the decode-side efficiency ordering must come out as the paper measures
it on hardware —

    tokens/joule(per-pool lock) >= tokens/joule(power cap)      (both archs)
    cap engaged on decode == False                              (the illusion)
    cap operating point == default operating point              (byte-identical)

Decode energy is now derived from MEASURED cache traffic: the paged pool's
TrafficCounter counts every block touched per step, and per-request joules
are power x bytes/bandwidth (repro.core.energy.joules_from_hbm_traffic) at
the pool's live operating point — not a shape-based estimate. The paged
pool also runs at a batch size the dense slot layout could not reach: the
block budget (kv_blocks x block_size tokens) would preallocate only
DENSE_SLOTS_AFFORDABLE dense rows of max_seq_len, and the benchmark asserts
the observed peak decode occupancy exceeds that.

Run:  PYTHONPATH=src python benchmarks/run.py              # full suite
  or: PYTHONPATH=src python -m benchmarks.serve_cluster    # this table only
  or: PYTHONPATH=src python -m benchmarks.serve_cluster --smoke   # CI tier
"""
from __future__ import annotations

import sys

import jax

from benchmarks.common import (
    VOLATILE_FIELDS,
    h200_model,
    write_bench_json,
    write_csv,
)
from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import ClockController, Cluster
from repro.training import make_prompts

# two DVFS classes: minicpm-2b is attention/full-MHA (batch-invariant-like),
# mamba2-780m is a recurrent SSM stack (compute-light/batch-sensitive side)
ARCHS = ("minicpm-2b", "mamba2-780m")
MODES = ("default", "cap", "lock")

MAX_SEQ_LEN = 128
KV_BLOCK_SIZE = 8
KV_BLOCKS = 80                  # 640 cache tokens of HBM budget
# the same budget as dense (max_seq_len-row) slots: the batch the old pool
# could reach before this refactor
DENSE_SLOTS_AFFORDABLE = KV_BLOCKS * KV_BLOCK_SIZE // MAX_SEQ_LEN


def serve_one(arch: str, mode: str, *, requests=14, batch=12, max_new=8):
    emodel = h200_model()
    cfg = reduced_config(arch)
    full = get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = make_prompts(cfg, requests, 8, 24, seed=11)
    ctl = ClockController(emodel, full, mode=mode)
    cluster = Cluster(
        cfg, params, controller=ctl, decode_batch=batch,
        max_seq_len=MAX_SEQ_LEN, prefill_chunk_tokens=128,
        meter_interval_s=0.01,
        paged=True, kv_block_size=KV_BLOCK_SIZE, kv_blocks=KV_BLOCKS,
    )
    for p in prompts:
        cluster.submit(p, max_new_tokens=max_new)
    done = cluster.run_to_completion()
    dec = cluster.decode_stats
    pool = cluster.decode_pool
    measured = cluster.measured_energy_j()
    return {
        "arch": arch,
        "mode": mode,
        "completed": len(done),
        "decode_tokens": dec.decode_tokens,
        "decode_j": dec.decode_j,
        "decode_tokens_per_j": dec.decode_tokens / dec.decode_j,
        "decode_read_bytes": dec.decode_read_bytes,
        "decode_write_bytes": dec.decode_write_bytes,
        "block_reads": pool.traffic.block_reads,
        "peak_occupancy": pool.peak_occupancy,
        "decode_clock_mhz": dec.actual_clock_mhz,
        "decode_engaged": dec.lever_engaged,
        "prefill_clock_mhz": cluster.prefill_stats.actual_clock_mhz,
        "total_j": cluster.stats.energy_j,
        "measured_prefill_j": measured["prefill"],
        "measured_decode_j": measured["decode"],
        "transitions": len(ctl.transitions),
    }


def run(smoke: bool = False, write_json: bool = False):
    """Harness contract: yields (name, us_per_call, derived) rows; raises if
    the paper's ordering is violated.

    ``smoke`` serves one architecture with a smaller request count — the CI
    slow-tier guard that keeps this benchmark from silently rotting."""
    archs = ARCHS[:1] if smoke else ARCHS
    requests = 10 if smoke else 14
    results = []
    out_rows = []
    violations = []
    for arch in archs:
        by_mode = {}
        for mode in MODES:
            r = serve_one(arch, mode, requests=requests)
            by_mode[mode] = r
            results.append(r)
            us_per_decode_tok = 1e6 * r["decode_j"] / max(r["decode_tokens"], 1)
            out_rows.append((
                f"serve_cluster/{arch}/{mode}",
                us_per_decode_tok,   # stands in for cost: uJ per decode token
                f"tok_per_j={r['decode_tokens_per_j']:.3f};"
                f"decode_clock={r['decode_clock_mhz']:.0f};"
                f"prefill_clock={r['prefill_clock_mhz']:.0f};"
                f"engaged={r['decode_engaged']};"
                f"peak_occ={r['peak_occupancy']};"
                f"MB_moved={(r['decode_read_bytes'] + r['decode_write_bytes']) / 1e6:.2f}",
            ))
            if r["completed"] != requests:
                violations.append(f"{arch}/{mode}: {r['completed']}/{requests} completed")
            if r["decode_read_bytes"] <= 0:
                violations.append(f"{arch}/{mode}: traffic meter saw no decode reads")
            # continuous batching over blocks must beat the dense slot count
            # the same HBM budget affords
            if r["peak_occupancy"] <= DENSE_SLOTS_AFFORDABLE:
                violations.append(
                    f"{arch}/{mode}: peak occupancy {r['peak_occupancy']} never "
                    f"exceeded the {DENSE_SLOTS_AFFORDABLE} dense slots the same "
                    f"budget affords"
                )
        # ---- the paper's ordering, asserted ------------------------------
        lock, cap, default = by_mode["lock"], by_mode["cap"], by_mode["default"]
        if lock["decode_tokens_per_j"] < cap["decode_tokens_per_j"]:
            violations.append(f"{arch}: lock tok/J < cap tok/J")
        if cap["decode_engaged"]:
            violations.append(f"{arch}: power cap ENGAGED on decode (paper says never)")
        if cap["decode_clock_mhz"] != default["decode_clock_mhz"]:
            violations.append(f"{arch}: inert cap drifted from the default clock")
        save_total = 100 * (1 - lock["total_j"] / default["total_j"])
        save_decode = 100 * (1 - default["decode_tokens_per_j"] / lock["decode_tokens_per_j"])
        out_rows.append((
            f"serve_cluster/{arch}/lock_savings",
            0.0,
            f"decode_energy_saved_pct={save_decode:.1f};"
            f"total_energy_saved_pct={save_total:.1f}",
        ))
    write_csv(
        "serve_cluster",
        list(results[0].keys()),
        [[r[k] for k in results[0].keys()] for r in results],
    )
    if write_json:
        path = write_bench_json(
            "serve_cluster",
            {f"{r['arch']}/{r['mode']}": r for r in results},
            smoke=smoke,
            # this benchmark serves on the REAL clock with threaded
            # samplers: its measured joules are wall-timing-dependent, so
            # they are volatile here (unlike serve_trace/serve_fleet, whose
            # virtual-time measurements are deterministic)
            volatile=VOLATILE_FIELDS | {"measured_prefill_j", "measured_decode_j"},
        )
        out_rows.append(("serve_cluster/json", 0.0, f"wrote={path}"))
    if violations:
        raise RuntimeError("; ".join(violations))
    return out_rows


def main():
    smoke = "--smoke" in sys.argv[1:]
    write_json = "--json" in sys.argv[1:]
    ok = True
    try:
        for name, us, derived in run(smoke=smoke, write_json=write_json):
            print(f"{name},{us:.1f},{derived}")
    except RuntimeError as e:
        print(f"ordering check VIOLATED: {e}")
        ok = False
    print("ordering check:", "OK" if ok else "VIOLATED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
