"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp reference, plus
the fused-vs-eager counterfactual from the energy model (§6.2/§7.2).

Wall-times here are CPU-interpret numbers (correctness-path); the *derived*
column reports the modelled TPU-side effect of fusion, which is the claim
that matters: fused MLA decode removes the kernel zoo, fused SSD/GDN
prefill collapses the order-of-magnitude eager penalty.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_models import PAPER_MODELS
from repro.core import Default, decode_workload, prefill_workload, resolve
from repro.kernels import (
    decode_attention,
    decode_attention_ref,
    gdn_prefill,
    gdn_scan_ref,
    mla_latent_decode,
    mla_latent_decode_ref,
    ssd_prefill,
    ssd_scan_ref,
)

from benchmarks.common import Row, h200_model, timed, write_csv


def _bench(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[Row]:
    key = jax.random.PRNGKey(0)
    rows: list[Row] = []
    csv_rows = []
    emodel = h200_model()

    # --- decode_attn ------------------------------------------------------
    B, H, KV, D, L = 2, 8, 2, 64, 512
    q = jax.random.normal(key, (B, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KV, D))
    vl = jnp.full((B,), L, jnp.int32)
    us_k = _bench(decode_attention, q, k, v, vl, scale=0.125, block_k=128)
    us_r = _bench(decode_attention_ref, q, k, v, vl, 0.125)
    csv_rows.append(["decode_attn", us_k, us_r])
    rows.append(("kernel_decode_attn", us_k, f"ref_us={us_r:.0f};interpret=True"))

    # --- mla_decode + modelled zoo elimination -----------------------------
    ql = jax.random.normal(key, (B, 16, 64))
    qr = jax.random.normal(jax.random.fold_in(key, 3), (B, 16, 16))
    ckv = jax.random.normal(jax.random.fold_in(key, 4), (B, L, 64))
    kr = jax.random.normal(jax.random.fold_in(key, 5), (B, L, 16))
    us_k = _bench(mla_latent_decode, ql, qr, ckv, kr, vl, scale=0.11, block_l=128)
    us_r = _bench(mla_latent_decode_ref, ql, qr, ckv, kr, vl, 0.11)
    mla = PAPER_MODELS["minitron-4b-mla"]()
    eager = resolve(emodel, decode_workload(mla, 1, 1024), Default())
    fused = resolve(emodel, decode_workload(mla, 1, 1024, fused=True), Default())
    gain = 1 - fused.energy_per_token_mj / eager.energy_per_token_mj
    csv_rows.append(["mla_decode", us_k, us_r])
    rows.append((
        "kernel_mla_decode", us_k,
        f"ref_us={us_r:.0f};modelled_decode_energy_gain={gain:.1%}",
    ))

    # --- ssd ---------------------------------------------------------------
    b, s, h, p, n = 1, 256, 8, 32, 64
    x = jax.random.normal(key, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 6), (b, s, h)))
    a = -jnp.exp(jnp.linspace(-2, 0.5, h))
    bm = jax.random.normal(jax.random.fold_in(key, 7), (b, s, n)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 8), (b, s, n)) * 0.3
    us_k = _bench(ssd_prefill, x, dt, a, bm, cm, q_chunk=64, head_block=4)
    us_r = _bench(ssd_scan_ref, x, dt, a, bm, cm)
    m2 = PAPER_MODELS["mamba2-4b"]()
    e_eager = resolve(emodel, prefill_workload(m2, 1, 4096), Default()).energy_per_token_mj
    e_fused = resolve(emodel, prefill_workload(m2, 1, 4096, fused=True), Default()).energy_per_token_mj
    csv_rows.append(["ssd_prefill", us_k, us_r])
    rows.append((
        "kernel_ssd", us_k,
        f"ref_us={us_r:.0f};modelled_prefill_mj {e_eager:.1f}->{e_fused:.1f}",
    ))

    # --- gdn ----------------------------------------------------------------
    q2 = jax.random.normal(key, (1, 128, 4, 32))
    q2 = q2 / jnp.linalg.norm(q2, axis=-1, keepdims=True)
    k2 = jax.random.normal(jax.random.fold_in(key, 9), (1, 128, 4, 32))
    k2 = k2 / jnp.linalg.norm(k2, axis=-1, keepdims=True)
    v2 = jax.random.normal(jax.random.fold_in(key, 10), (1, 128, 4, 32)) * 0.5
    beta = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 11), (1, 128, 4)))
    alpha = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 12), (1, 128, 4)) + 2)
    us_k = _bench(gdn_prefill, q2, k2, v2, beta, alpha, q_chunk=32)
    us_r = _bench(gdn_scan_ref, q2, k2, v2, beta, alpha)
    gdn = PAPER_MODELS["gdn-4b"]()
    e_eager = resolve(emodel, prefill_workload(gdn, 1, 4096), Default()).energy_per_token_mj
    e_fused = resolve(emodel, prefill_workload(gdn, 1, 4096, fused=True), Default()).energy_per_token_mj
    csv_rows.append(["gdn_prefill", us_k, us_r])
    rows.append((
        "kernel_gdn", us_k,
        f"ref_us={us_r:.0f};modelled_prefill_mj {e_eager:.1f}->{e_fused:.1f} ({e_eager/e_fused:.1f}x)",
    ))

    write_csv("kernels_micro", ["kernel", "pallas_interpret_us", "ref_us"], csv_rows)
    return rows
