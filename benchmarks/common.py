"""Shared benchmark plumbing: timing, CSV artefacts, model/lever fixtures,
and the one ``--json`` perf-record writer every serving benchmark shares."""
from __future__ import annotations

import csv
import json
import os
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs.paper_models import PAPER_MODELS, PARADIGM
from repro.core import EnergyModel
from repro.hw import H200_SXM, TPU_V5E

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/benchmarks")

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

# ---------------------------------------------------------- bench JSON record
# version of the committed perf-record layout; bump on breaking field changes
BENCH_SCHEMA_VERSION = 1
# field names that vary run-to-run (wall timings) and must never land in the
# committed record — the JSON stays byte-stable unless serving behaviour
# actually changed
VOLATILE_FIELDS = frozenset({"wall_s", "wall_secs", "wall_time_s"})


def deterministic_fields(obj: Any, volatile=VOLATILE_FIELDS) -> Any:
    """Recursively drop volatile (wall-clock) keys from a JSON-able tree."""
    if isinstance(obj, dict):
        return {k: deterministic_fields(v, volatile)
                for k, v in obj.items() if k not in volatile}
    if isinstance(obj, (list, tuple)):
        return [deterministic_fields(v, volatile) for v in obj]
    return obj


def write_bench_json(
    bench: str,
    results: Any,
    *,
    smoke: bool = False,
    trace: Optional[Dict[str, Any]] = None,
    extra: Optional[Dict[str, Any]] = None,
    path: Optional[str] = None,
    volatile=VOLATILE_FIELDS,
) -> str:
    """The shared ``--json`` writer (serve_cluster / serve_trace /
    serve_fleet): schema-versioned payload, volatile fields filtered, keys
    sorted — so two identical replays produce byte-identical artefacts."""
    payload: Dict[str, Any] = {
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "smoke": smoke,
        "results": deterministic_fields(results, volatile),
    }
    if trace is not None:
        payload["trace"] = deterministic_fields(trace, volatile)
    if extra:
        payload.update(deterministic_fields(extra, volatile))
    path = path or f"BENCH_{bench}.json"
    with open(path, "w") as f:
        json.dump(payload, f, sort_keys=True, indent=1)
    return path


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def paper_models():
    return {k: v() for k, v in PAPER_MODELS.items()}


def h200_model() -> EnergyModel:
    return EnergyModel(H200_SXM)


def v5e_model() -> EnergyModel:
    return EnergyModel(TPU_V5E)
