"""Shared benchmark plumbing: timing, CSV artefacts, model/lever fixtures."""
from __future__ import annotations

import csv
import os
import time
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

from repro.configs.paper_models import PAPER_MODELS, PARADIGM
from repro.core import EnergyModel
from repro.hw import H200_SXM, TPU_V5E

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/benchmarks")

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def paper_models():
    return {k: v() for k, v in PAPER_MODELS.items()}


def h200_model() -> EnergyModel:
    return EnergyModel(H200_SXM)


def v5e_model() -> EnergyModel:
    return EnergyModel(TPU_V5E)
