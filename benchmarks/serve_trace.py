"""Virtual-time trace replay: default / cap / lock / slo across two archs.

The §7.1 recipe as an SLO statement. A seeded Poisson arrival trace is
replayed through the paged prefill/decode cluster in VIRTUAL time — step
durations come from the energy model at each pool's live operating point,
idle joules accrue between bursts, and every request's ledger yields
TTFT/TBT percentiles — under four controller modes:

    default  governor clock (baseline)
    cap      the industry reflex (must stay INERT on decode)
    lock     the paper's static policy-table fix
    slo      the closed loop: policy prior + measured-p99 grid walk

Asserted, per architecture:

    cap never engages on decode and its clock == default's  (the illusion)
    slo meets its p99 TBT target
    slo decode joules <= lock decode joules whenever lock ALSO meets the
        target  (the loop only ever refines the table downward in energy)
    the replay is deterministic: two runs -> byte-identical JSON

SLO targets are derived from the model, not hand-tuned: the TBT target is
a fixed multiple of the modelled floor-clock step time plus the worst
chunked-prefill interleave a tick can add; TTFT gets the queueing headroom
a 35%-utilisation Poisson load needs.

Run:  PYTHONPATH=src python -m benchmarks.serve_trace            # full (500-req traces)
  or: PYTHONPATH=src python -m benchmarks.serve_trace --smoke    # CI tier
  add --json to write BENCH_serve_trace.json (the perf-record artefact)
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax

from benchmarks.common import h200_model, write_bench_json, write_csv
from repro.configs import get_config, reduced_config
from repro.core import VirtualClock, decode_workload, generate_trace, prefill_workload
from repro.core.latency import summarize_latency
from repro.models import init_params
from repro.serving import ClockSpec, Cluster, PoolSpec, ReplicaSpec

ARCHS = ("minicpm-2b", "mamba2-780m")
MODES = ("default", "cap", "lock", "slo")

BATCH = 12
MAX_SEQ_LEN = 128
KV_BLOCK_SIZE = 8
KV_BLOCKS = 96                      # 768 cache tokens of HBM budget
CHUNK_TOKENS = 64
CTX_EST = 48                        # mean live context for capacity estimates
MEAN_NEW = 16                       # short_chat mean decode budget
UTILISATION = 0.35                  # arrival rate as a fraction of capacity
TRACE_SEED = 17
JSON_PATH = "BENCH_serve_trace.json"
# wall-clock budget for one 500-request replay (the acceptance bar); 0 waives
TIME_BUDGET_S = float(os.environ.get("REPRO_TRACE_TIME_BUDGET_S", "60"))


def slo_targets(emodel, full_cfg):
    """Model-derived SLO targets + the matching Poisson arrival rate."""
    f_floor = min(emodel.clock_grid())
    t_dec = emodel.profile(decode_workload(full_cfg, BATCH, CTX_EST), f_floor).t_total
    # worst chunked-prefill interleave per tick: ~CHUNK_TOKENS of prompt at
    # the prefill pool's (high) clock
    wp = prefill_workload(full_cfg, 1, 4096)
    prof_p = emodel.profile(wp, emodel.spec.f_max)
    t_chunk = prof_p.t_total / prof_p.tokens * CHUNK_TOKENS
    tbt_s = 2.0 * (t_dec + t_chunk)
    ttft_s = 100.0 * tbt_s
    capacity_rps = BATCH / t_dec / MEAN_NEW
    return tbt_s, ttft_s, UTILISATION * capacity_rps


def replica_spec(arch: str, mode: str, tbt_s: float, ttft_s: float) -> ReplicaSpec:
    """The declarative build: one spec describes the whole replica."""
    return ReplicaSpec(
        name=f"{arch}-{mode}",
        arch=arch,
        clock=ClockSpec(mode=mode, context=CTX_EST,
                        slo_tbt_s=tbt_s, slo_ttft_s=ttft_s),
        decode=PoolSpec(batch=BATCH, paged=True,
                        kv_block_size=KV_BLOCK_SIZE, kv_blocks=KV_BLOCKS),
        max_seq_len=MAX_SEQ_LEN,
        prefill_chunk_tokens=CHUNK_TOKENS,
    )


def replay(arch: str, mode: str, trace, tbt_s: float, ttft_s: float):
    """One virtual-time replay; returns (deterministic metrics, wall secs)."""
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cluster = Cluster.from_spec(
        replica_spec(arch, mode, tbt_s, ttft_s),
        emodel=h200_model(), params=params, clock=VirtualClock(),
    )
    ctl = cluster.controller
    t0 = time.perf_counter()
    done = cluster.run_trace(trace)
    wall_s = time.perf_counter() - t0
    lat = summarize_latency(done)
    dec = cluster.decode_stats
    measured = cluster.measured_energy_j()
    return {
        "arch": arch,
        "mode": mode,
        "completed": len(done),
        "requests": len(trace),
        "decode_tokens": dec.decode_tokens,
        "decode_j": dec.decode_j,
        "j_per_decode_token": dec.decode_j / max(dec.decode_tokens, 1),
        "decode_tokens_per_vs": dec.decode_tokens / max(dec.decode_s, 1e-12),
        "virtual_makespan_s": dec.decode_s + cluster.prefill_stats.prefill_s,
        "p50_ttft_s": lat.p50_ttft_s,
        "p99_ttft_s": lat.p99_ttft_s,
        "p50_tbt_s": lat.p50_tbt_s,
        "p99_tbt_s": lat.p99_tbt_s,
        "p99_e2e_s": lat.p99_e2e_s,
        "slo_met": lat.meets(ttft_s=ttft_s, tbt_s=tbt_s),
        "decode_clock_mhz": dec.actual_clock_mhz,
        "decode_engaged": dec.lever_engaged,
        "prefill_clock_mhz": cluster.prefill_stats.actual_clock_mhz,
        "measured_decode_j": measured["decode"],
        "measured_prefill_j": measured["prefill"],
        "transitions": len(ctl.transitions),
        "preemptions": sum(r.preemptions for r in done),
        "tbt_target_s": tbt_s,
        "ttft_target_s": ttft_s,
    }, wall_s


def run(smoke: bool = False, write_json: bool = False):
    """Harness contract: yields (name, us_per_call, derived) rows; raises on
    any violated ordering/SLO/determinism assertion."""
    n_requests = 60 if smoke else 500
    results = {}
    out_rows = []
    violations = []
    wall_by_run = {}
    for arch in ARCHS:
        emodel = h200_model()
        full = get_config(arch)
        tbt_s, ttft_s, rate_rps = slo_targets(emodel, full)
        trace = generate_trace(
            reduced_config(arch), n_requests, arrival="poisson",
            lengths="short_chat", rate_rps=rate_rps, seed=TRACE_SEED,
            max_total_len=MAX_SEQ_LEN,
        )
        by_mode = {}
        for mode in MODES:
            r, wall_s = replay(arch, mode, trace, tbt_s, ttft_s)
            by_mode[mode] = r
            results[f"{arch}/{mode}"] = r
            wall_by_run[f"{arch}/{mode}"] = wall_s
            out_rows.append((
                f"serve_trace/{arch}/{mode}",
                1e6 * r["j_per_decode_token"],       # uJ per decode token
                f"tok_per_vs={r['decode_tokens_per_vs']:.1f};"
                f"p99_tbt_ms={1e3 * r['p99_tbt_s']:.3f};"
                f"p99_ttft_ms={1e3 * r['p99_ttft_s']:.2f};"
                f"clock={r['decode_clock_mhz']:.0f};"
                f"slo_met={r['slo_met']};transitions={r['transitions']}",
            ))
            if r["completed"] != n_requests:
                violations.append(
                    f"{arch}/{mode}: {r['completed']}/{n_requests} completed")
        # ---- the claims, asserted ---------------------------------------
        cap, default = by_mode["cap"], by_mode["default"]
        lock, slo = by_mode["lock"], by_mode["slo"]
        if cap["decode_engaged"]:
            violations.append(f"{arch}: power cap ENGAGED on decode")
        if cap["decode_clock_mhz"] != default["decode_clock_mhz"]:
            violations.append(f"{arch}: inert cap drifted from default clock")
        if not slo["slo_met"]:
            violations.append(
                f"{arch}: slo mode missed its target "
                f"(p99 TBT {slo['p99_tbt_s']:.4f}s vs {tbt_s:.4f}s)")
        if lock["slo_met"] and slo["decode_j"] > lock["decode_j"] * (1 + 1e-9):
            violations.append(
                f"{arch}: slo decode energy {slo['decode_j']:.3f}J exceeds "
                f"lock's {lock['decode_j']:.3f}J though both meet the SLO")
        out_rows.append((
            f"serve_trace/{arch}/slo_vs_lock",
            0.0,
            f"slo_j={slo['decode_j']:.3f};lock_j={lock['decode_j']:.3f};"
            f"saved_pct={100 * (1 - slo['decode_j'] / lock['decode_j']):.2f};"
            f"slo_clock={slo['decode_clock_mhz']:.0f};"
            f"lock_clock={lock['decode_clock_mhz']:.0f}",
        ))
    # ---- determinism: a second replay must be byte-identical -------------
    arch = ARCHS[0]
    emodel = h200_model()
    tbt_s, ttft_s, rate_rps = slo_targets(emodel, get_config(arch))
    trace = generate_trace(
        reduced_config(arch), n_requests, arrival="poisson",
        lengths="short_chat", rate_rps=rate_rps, seed=TRACE_SEED,
        max_total_len=MAX_SEQ_LEN,
    )
    again, wall_again = replay(arch, "slo", trace, tbt_s, ttft_s)
    blob_a = json.dumps(results[f"{arch}/slo"], sort_keys=True)
    blob_b = json.dumps(again, sort_keys=True)
    if blob_a != blob_b:
        violations.append(f"{arch}/slo: replay NOT deterministic")
    out_rows.append((
        "serve_trace/determinism", 0.0,
        f"byte_identical={blob_a == blob_b};requests={n_requests}",
    ))
    if not smoke and TIME_BUDGET_S > 0:
        slowest = max(wall_by_run.values())
        if slowest > TIME_BUDGET_S:
            violations.append(
                f"a {n_requests}-request replay took {slowest:.1f}s "
                f"(> {TIME_BUDGET_S:.0f}s budget)")
        out_rows.append((
            "serve_trace/wall_time", 0.0,
            f"slowest_replay_s={slowest:.1f};budget_s={TIME_BUDGET_S:.0f}",
        ))
    keys = list(next(iter(results.values())).keys())
    write_csv("serve_trace", keys, [[r[k] for k in keys] for r in results.values()])
    if write_json:
        write_bench_json(
            "serve_trace", results, smoke=smoke, path=JSON_PATH,
            trace={"n": n_requests, "arrival": "poisson",
                   "lengths": "short_chat", "seed": TRACE_SEED},
        )
        out_rows.append(("serve_trace/json", 0.0, f"wrote={JSON_PATH}"))
    if violations:
        raise RuntimeError("; ".join(violations))
    return out_rows


def main():
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    write_json = "--json" in argv
    ok = True
    try:
        for name, us, derived in run(smoke=smoke, write_json=write_json):
            print(f"{name},{us:.1f},{derived}")
    except RuntimeError as e:
        print(f"serve_trace checks VIOLATED: {e}")
        ok = False
    print("serve_trace checks:", "OK" if ok else "VIOLATED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
