"""Event-engine fleet replay at scale: 10^5 requests over 16 replicas.

The discrete-event serving core (``repro.serving.events``) replaces the
round barrier with a per-fleet event heap: replica prefill/decode pools
run independent virtual timelines that meet only at handoff and routing,
and homogeneous decode events at the same instant collapse into ONE
fused jitted dispatch. This benchmark is its scale + determinism gate:

    replay        16-replica qwen-class fleet, 10^5 aligned requests
                  (waves of 16 identical prompts at one-step cadence, so
                  every replica's decode event lands on the same instant
                  and the fused fast path carries the whole run)
    determinism   the replay runs twice; a sha256 over every request's
                  outputs + ledger stamps must match byte-for-byte
    fused         the fused dispatch cache must be exercised, and fused
                  calls must cover the large majority of decode steps
    overlap       on a prefill-burst trace (long prompts landing mid-
                  decode) the event engine's p99 TTFT must be strictly
                  better than the barrier driver's on the SAME trace —
                  the timing bug the barrier was hiding, quantified

Asserted:

    all requests complete, both replays byte-identical
    fused calls > 0 and >= 80% of decode steps ran fused
    event p99 TTFT < barrier p99 TTFT on the burst trace
    slowest single replay fits the wall budget
        (REPRO_EVENTS_TIME_BUDGET_S, default 1800 s; 0 waives)

Run:  PYTHONPATH=src python -m benchmarks.serve_events            # full
  or: PYTHONPATH=src python -m benchmarks.serve_events --smoke    # CI tier
  add --json to write BENCH_serve_events.json (the perf-record artefact)
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
import time

import jax
import numpy as np

from benchmarks.common import h200_model, write_bench_json, write_csv
from repro.configs import reduced_config
from repro.core.latency import summarize_latency
from repro.core.traces import TracedRequest
from repro.models import init_params
from repro.serving import (
    ClockSpec,
    EventDrivenFleet,
    Fleet,
    FleetSpec,
    PoolSpec,
    ReplicaSpec,
)

ARCH = "gemma-2b"
N_REPLICAS = 16
BATCH = 8
MAX_SEQ_LEN = 64
CHUNK_TOKENS = 64
PROMPT_LEN = 16
MAX_NEW = 4
WAVE_DT_S = 0.0021                  # ~ one locked-clock decode step
TRACE_SEED = 17
JSON_PATH = "BENCH_serve_events.json"
# wall-clock budget for ONE replay (the acceptance bar); 0 waives
TIME_BUDGET_S = float(os.environ.get("REPRO_EVENTS_TIME_BUDGET_S", "1800"))

_PARAMS_CACHE = {}


def params_for():
    if ARCH not in _PARAMS_CACHE:
        _PARAMS_CACHE[ARCH] = init_params(
            reduced_config(ARCH), jax.random.PRNGKey(0))
    return _PARAMS_CACHE


def make_fleet(n=N_REPLICAS, *, batch=BATCH, max_seq_len=MAX_SEQ_LEN,
               chunk=CHUNK_TOKENS) -> Fleet:
    spec = FleetSpec(
        replicas=tuple(
            ReplicaSpec(name=f"r{i:02d}", arch=ARCH,
                        clock=ClockSpec(mode="lock"),
                        decode=PoolSpec(batch=batch),
                        max_seq_len=max_seq_len,
                        prefill_chunk_tokens=chunk)
            for i in range(n)),
        router="jsq",
    )
    return Fleet.from_spec(spec, emodel=h200_model(), params_for=params_for())


def wave_trace(n_requests: int):
    """Waves of ``N_REPLICAS`` identical prompts at one-step cadence: JSQ
    spreads one per replica, the replicas stay in lockstep, and every
    decode instant is shared fleet-wide — the fused fast path's shape.

    Returns ``(trace, dropped)``: requests that don't fill a whole wave
    are dropped (a partial wave would break the alignment the benchmark
    is asserting) — callers must surface ``dropped`` instead of silently
    reporting the requested count."""
    rng = np.random.default_rng(TRACE_SEED)
    prompt = rng.integers(1, 100, PROMPT_LEN).astype(np.int32)
    n_waves = n_requests // N_REPLICAS
    trace = [
        TracedRequest(arrival_s=w * WAVE_DT_S, prompt=prompt,
                      max_new_tokens=MAX_NEW, bucket="mixed")
        for w in range(n_waves) for _ in range(N_REPLICAS)
    ]
    return trace, n_requests - len(trace)


def burst_trace():
    """One long-decode request, then long prompts landing mid-decode —
    the shape where the barrier's admission-serialises-decode timing bug
    costs TTFT (mirrors tests/test_events.py::TestOverlap)."""
    def req(plen, arr, max_new, seed):
        rng = np.random.default_rng(seed + plen)
        return TracedRequest(
            arrival_s=arr,
            prompt=rng.integers(1, 100, plen).astype(np.int32),
            max_new_tokens=max_new, bucket="mixed")

    return [req(8, 0.0, 24, seed=1)] + [
        req(480, 1e-4 * (i + 1), 4, seed=2 + i) for i in range(4)]


def replay(trace):
    """One event-engine replay; returns (metrics, replay sha256, wall s)."""
    fleet = make_fleet()
    eng = EventDrivenFleet(fleet)
    t0 = time.perf_counter()
    done = eng.run(trace, max_steps=10_000_000)
    wall_s = time.perf_counter() - t0
    done = sorted(done, key=lambda r: (r.ledger.arrival_s, r.replica, r.uid))
    lat = summarize_latency(done)
    blob = json.dumps({
        "outputs": [r.output for r in done],
        "stamps": [[r.ledger.arrival_s, r.ledger.admitted_s,
                    r.ledger.first_token_s, r.ledger.finish_s]
                   for r in done],
        "measured_j": fleet.measured_energy_j(),
    }, sort_keys=True)
    st = eng.stats
    metrics = {
        "completed": len(done),
        "requests": len(trace),
        "replicas": len(fleet.replicas),
        "decode_steps": eng._steps,
        "fused_calls": eng.fused_calls,
        "fused_step_pct": 100.0 * st.fused_decode_coverage,
        "decode_tokens": fleet.stats.decode_tokens,
        "total_j": fleet.total_energy_j(),
        "p50_ttft_s": lat.p50_ttft_s,
        "p99_ttft_s": lat.p99_ttft_s,
        "p99_tbt_s": lat.p99_tbt_s,
        "engine_stats": st.as_dict(),
    }
    return metrics, hashlib.sha256(blob.encode()).hexdigest(), wall_s


def run(smoke: bool = False, write_json: bool = False):
    """Harness contract: yields (name, us_per_call, derived) rows; raises
    on any violated completion/determinism/fusion/overlap assertion."""
    n_requested = 2_000 if smoke else 100_000
    trace, dropped = wave_trace(n_requested)
    n_requests = len(trace)             # whole waves only
    if dropped:
        print(f"serve_events: dropped {dropped} of {n_requested} requests "
              f"(whole {N_REPLICAS}-request waves only)", file=sys.stderr)

    out_rows = []
    violations = []

    first, sha_a, wall_a = replay(trace)
    again, sha_b, wall_b = replay(trace)
    out_rows.append((
        "serve_events/replay",
        1e6 * wall_a / n_requests,
        f"requests={n_requests};dropped={dropped};"
        f"replicas={first['replicas']};"
        f"steps={first['decode_steps']};total_j={first['total_j']:.3f};"
        f"p99_ttft_ms={1e3 * first['p99_ttft_s']:.3f};"
        f"wall_s={wall_a:.1f}",
    ))
    if first["completed"] != n_requests:
        violations.append(
            f"replay: {first['completed']}/{n_requests} completed")

    # ---- byte-identical across replays -----------------------------------
    identical = sha_a == sha_b and first == again
    if not identical:
        violations.append("replay NOT byte-identical across runs")
    out_rows.append((
        "serve_events/determinism", 0.0,
        f"byte_identical={identical};sha={sha_a[:16]}",
    ))

    # ---- prefix sharing defaults off: this fleet must be untouched by it --
    es = first["engine_stats"]
    if (es["prefix_hits"], es["prefix_cow_splits"],
            es["saved_prefill_j"]) != (0, 0, 0.0):
        violations.append(
            f"prefix sharing leaked into a sharing-off fleet: "
            f"hits={es['prefix_hits']} cow={es['prefix_cow_splits']} "
            f"saved_j={es['saved_prefill_j']}")

    # ---- the fused fast path carried the run -----------------------------
    if first["fused_calls"] == 0:
        violations.append("fused fast path never fired")
    if first["fused_step_pct"] < 80.0:
        violations.append(
            f"only {first['fused_step_pct']:.1f}% of decode steps ran "
            f"fused (want >= 80%)")
    out_rows.append((
        "serve_events/fused", 0.0,
        f"fused_calls={first['fused_calls']};"
        f"fused_step_pct={first['fused_step_pct']:.1f}",
    ))

    # ---- overlap: event p99 TTFT strictly beats the barrier --------------
    burst = burst_trace()
    p99 = {}
    for engine in ("events", "barrier"):
        fleet = make_fleet(1, batch=4, max_seq_len=512, chunk=512)
        done = fleet.run_trace(burst, engine=engine)
        if len(done) != len(burst):
            violations.append(
                f"overlap/{engine}: {len(done)}/{len(burst)} completed")
        p99[engine] = summarize_latency(done).p99_ttft_s
    if not p99["events"] < p99["barrier"]:
        violations.append(
            f"overlap: event p99 TTFT {p99['events']:.6f}s not strictly "
            f"better than barrier's {p99['barrier']:.6f}s")
    out_rows.append((
        "serve_events/overlap_vs_barrier", 0.0,
        f"events_p99_ttft_ms={1e3 * p99['events']:.3f};"
        f"barrier_p99_ttft_ms={1e3 * p99['barrier']:.3f};"
        f"saved_pct={100 * (1 - p99['events'] / p99['barrier']):.1f}",
    ))

    # ---- wall budget ------------------------------------------------------
    slowest = max(wall_a, wall_b)
    if TIME_BUDGET_S > 0:
        if slowest > TIME_BUDGET_S:
            violations.append(
                f"a replay took {slowest:.1f}s (> {TIME_BUDGET_S:.0f}s budget)")
        out_rows.append((
            "serve_events/wall_time", 0.0,
            f"slowest_replay_s={slowest:.1f};budget_s={TIME_BUDGET_S:.0f}",
        ))

    results = {"replay": first, "replay_sha": sha_a,
               "overlap_p99_ttft_s": p99, "wall_s": [wall_a, wall_b]}
    write_csv("serve_events", ["metric", "value"],
              [[k, v] for k, v in first.items()]
              + [["events_p99_ttft_s", p99["events"]],
                 ["barrier_p99_ttft_s", p99["barrier"]]])
    if write_json:
        write_bench_json(
            "serve_events", results, smoke=smoke, path=JSON_PATH,
            trace={"n": n_requests, "n_requested": n_requested,
                   "dropped": dropped, "shape": "aligned-waves",
                   "wave_dt_s": WAVE_DT_S, "prompt_len": PROMPT_LEN,
                   "max_new": MAX_NEW, "seed": TRACE_SEED},
        )
        out_rows.append(("serve_events/json", 0.0, f"wrote={JSON_PATH}"))
    if violations:
        raise RuntimeError("; ".join(violations))
    return out_rows


def main():
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    write_json = "--json" in argv
    ok = True
    try:
        for name, us, derived in run(smoke=smoke, write_json=write_json):
            print(f"{name},{us:.1f},{derived}")
    except RuntimeError as e:
        print(f"serve_events checks VIOLATED: {e}")
        ok = False
    print("serve_events checks:", "OK" if ok else "VIOLATED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
