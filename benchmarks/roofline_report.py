"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artefacts (results/dryrun/*.json).

    compute    = HLO_FLOPs_per_device / peak_bf16      (197 TFLOP/s)
    memory     = HLO_bytes_per_device / hbm_bw         (819 GB/s)
    collective = collective_bytes_per_device / link_bw (50 GB/s)

plus the dominant term, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a
next-lever note per cell. Emits results/benchmarks/roofline.csv and a
markdown table (results/benchmarks/roofline.md) that EXPERIMENTS.md embeds.
"""
from __future__ import annotations

import glob
import json
import os

from repro.hw import TPU_V5E

from benchmarks.common import RESULTS_DIR, Row, timed, write_csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "results/dryrun")

NEXT_LEVER = {
    "compute": "raise arithmetic efficiency: reduce remat/recompute, bigger microbatch GEMMs",
    "memory": "cut HBM traffic: fuse activations, cache-friendly layouts, lower-precision cache",
    "collective": "reshard to remove all-gathers; overlap collectives with compute",
}


def analyse_cell(rec: dict) -> dict | None:
    if not rec.get("applicable", True) or not rec.get("ok"):
        return None
    spec = TPU_V5E
    n_dev = rec["n_devices"]
    flops = rec["hlo_flops_per_device"]
    bytes_ = rec["hlo_bytes_per_device"]
    coll = rec["collective_bytes_per_device"]
    t_c = flops / spec.peak_flops_bf16
    t_m = bytes_ / spec.hbm_bw
    t_x = coll / spec.ici_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])[0]
    model_flops = rec["model_flops_per_step"]
    useful = model_flops / max(flops * n_dev, 1.0)
    t_bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom,
        "t_bound_s": t_bound,
        "model_flops_per_step": model_flops,
        "hlo_flops_global": flops * n_dev,
        "useful_flops_ratio": useful,
        "collective_count": rec.get("collective_count", 0),
        "next_lever": NEXT_LEVER[dom],
    }


def load_cells(dryrun_dir: str = DRYRUN_DIR):
    """Prefer exact-accounting (unrolled) artefacts; fall back to scanned
    ones (which undercount while-body costs — see dryrun docstring)."""
    by_cell = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"))
        unrolled = rec.get("unrolled_accounting", False)
        if key not in by_cell or (unrolled and not by_cell[key].get("unrolled_accounting")):
            if rec.get("ok") or key not in by_cell:
                by_cell[key] = rec
    cells = []
    for rec in by_cell.values():
        row = analyse_cell(rec)
        if row:
            row["accounting"] = "unrolled" if rec.get("unrolled_accounting") else "scanned"
            cells.append(row)
    return sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"]))


def to_markdown(cells) -> str:
    hdr = (
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful FLOPs | acct | next lever |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for c in cells:
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['t_compute_s']*1e3:.2f} | {c['t_memory_s']*1e3:.2f} "
            f"| {c['t_collective_s']*1e3:.2f} | **{c['dominant']}** "
            f"| {c['useful_flops_ratio']:.2f} | {c.get('accounting','scanned')[:3]} "
            f"| {c['next_lever']} |"
        )
    return hdr + "\n".join(lines) + "\n"


def run() -> list[Row]:
    def build():
        cells = load_cells()
        single = [c for c in cells if c["mesh"] == "pod16x16"]
        multi = [c for c in cells if c["mesh"] == "pod2x16x16"]
        if cells:
            write_csv(
                "roofline",
                list(cells[0]),
                [[c[k] for k in cells[0]] for c in cells],
            )
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
                f.write("## Single-pod (16x16) baseline roofline — the §Roofline table\n\n")
                f.write(to_markdown(single))
                f.write("\n## Multi-pod (2x16x16) — pod-axis sharding check\n\n")
                f.write(to_markdown(multi))
        return cells, single, multi

    (cells, single, multi), us = timed(build)
    if not cells:
        return [("roofline_report", us, "no dryrun artefacts found (run repro.launch.dryrun)")]
    doms = {}
    for c in single:
        doms[c["dominant"]] = doms.get(c["dominant"], 0) + 1
    derived = (
        f"single_pod_cells={len(single)};multi_pod_cells={len(multi)};"
        + ";".join(f"{k}={v}" for k, v in sorted(doms.items()))
    )
    return [("roofline_report", us, derived)]
