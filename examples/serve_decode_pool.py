"""End-to-end serving driver: a decode pool with a phase-aware clock policy.

The paper's deployment recipe (§7.1): disaggregated pools lock each phase's
optimal clock statically. This example runs a real continuous-batching
engine over batched requests (reduced model on CPU), meters wall-clock
energy with the 50 ms sampler against the modelled power source, and
compares three operating modes end to end:

    default      — governor, no lever (the baseline everyone runs)
    power-cap    — lowest cap (the industry default; inert for decode)
    clock-lock   — the policy table's decode clock (the paper's fix)

Run:  PYTHONPATH=src python examples/serve_decode_pool.py --arch minicpm-2b
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (
    ClockLock,
    Default,
    EnergyModel,
    EnergyMeter,
    PowerCap,
    best_clock,
    decode_workload,
    prefill_workload,
    resolve,
)
from repro.hw import TPU_V5E
from repro.models import init_params
from repro.serving import ServingEngine
from repro.training import make_prompts


class PhaseMeteredRun:
    def __init__(self, emodel, full_cfg, lever, batch):
        self.emodel = emodel
        self.cfg = full_cfg
        self.lever = lever
        self.batch = batch

    def power_during(self, phase: str) -> float:
        if phase == "prefill":
            w = prefill_workload(self.cfg, 1, 1024, fused=True)
        else:
            w = decode_workload(self.cfg, self.batch, 1024, fused=True)
        return resolve(self.emodel, w, self.lever).power_w

    def run(self, cfg, params, prompts, max_new):
        engine = ServingEngine(cfg, params, max_batch=self.batch, max_seq_len=256)
        for p in prompts:
            engine.submit(p, max_new_tokens=max_new)
        phase = {"current": "decode"}
        with EnergyMeter(lambda: self.power_during(phase["current"]), interval_s=0.01) as meter:
            done = engine.run_to_completion()
        stats = engine.stats
        # analytic per-token energies at this operating point
        dec = resolve(self.emodel, decode_workload(self.cfg, self.batch, 1024, fused=True), self.lever)
        pre = resolve(self.emodel, prefill_workload(self.cfg, 1, 1024, fused=True), self.lever)
        modelled_j = (
            dec.energy_per_token_mj * stats.decode_tokens
            + pre.energy_per_token_mj * stats.prefill_tokens
        ) / 1e3
        return {
            "completed": len(done),
            "decode_tokens": stats.decode_tokens,
            "prefill_tokens": stats.prefill_tokens,
            "decode_power_w": dec.power_w,
            "decode_mj_per_tok": dec.energy_per_token_mj,
            "request_energy_j_modelled": modelled_j,
            "tput_loss_vs_default": None,  # filled by caller
            "clock_mhz": dec.actual_clock_mhz,
            "engaged": dec.engaged,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    full = get_config(args.arch)
    emodel = EnergyModel(TPU_V5E)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = make_prompts(cfg, args.requests, 8, 32)

    rec_clock = best_clock(emodel, decode_workload(full, args.batch, 1024, fused=True)).clock_mhz
    modes = [
        ("default", Default()),
        (f"power-cap {emodel.spec.power_cap_levels[0]:.0f}W", PowerCap(emodel.spec.power_cap_levels[0])),
        (f"clock-lock {rec_clock:.0f}MHz", ClockLock(rec_clock)),
    ]
    base_e = None
    for name, lever in modes:
        out = PhaseMeteredRun(emodel, full, lever, args.batch).run(
            cfg, params, prompts, args.max_new
        )
        if base_e is None:
            base_e = out["request_energy_j_modelled"]
        save = 100 * (1 - out["request_energy_j_modelled"] / base_e)
        print(
            f"[{name:22s}] clock={out['clock_mhz']:5.0f}MHz engaged={str(out['engaged']):5s} "
            f"P_dec={out['decode_power_w']:6.1f}W E={out['request_energy_j_modelled']:8.2f}J "
            f"savings={save:5.1f}% ({out['completed']} reqs, {out['decode_tokens']} decode tok)"
        )
    print("\nconclusion: the cap changes nothing; the lock banks the savings —"
          " the paper's Fig 3, live.")


if __name__ == "__main__":
    main()
