"""End-to-end serving driver: a disaggregated cluster with a phase-aware
clock controller.

The paper's deployment recipe (§7.1): disaggregated pools lock each phase's
optimal clock statically. This example runs the real prefill/decode cluster
(reduced model on CPU) under the online ``ClockController`` — each pool's
``PowerSampler`` meters the modelled power of that pool's live operating
point — and compares three operating modes end to end:

    default      — governor, no lever (the baseline everyone runs)
    power-cap    — lowest cap (the industry default; inert for decode)
    clock-lock   — per-pool policy-table locks (the paper's fix)

Run:  PYTHONPATH=src python examples/serve_decode_pool.py --arch minicpm-2b
"""
import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.core import EnergyModel
from repro.hw import H200_SXM
from repro.models import init_params
from repro.serving import ClockController, Cluster
from repro.training import make_prompts


def run_mode(mode, cfg, full, params, prompts, args):
    emodel = EnergyModel(H200_SXM)
    ctl = ClockController(emodel, full, mode=mode)
    cluster = Cluster(
        cfg, params,
        controller=ctl,
        decode_batch=args.batch,
        max_seq_len=256,
        prefill_chunk_tokens=args.chunk,
        meter_interval_s=0.01,
        paged=args.paged,
        kv_block_size=16,
        kv_blocks=args.kv_blocks,
    )
    for p in prompts:
        cluster.submit(p, max_new_tokens=args.max_new)
    done = cluster.run_to_completion()
    s = cluster.stats
    dec = cluster.decode_stats
    return {
        "completed": len(done),
        "decode_tokens": s.decode_tokens,
        "prefill_tokens": s.prefill_tokens,
        "energy_j": s.energy_j,
        "decode_clock": dec.actual_clock_mhz,
        "prefill_clock": cluster.prefill_stats.actual_clock_mhz,
        "decode_engaged": dec.lever_engaged,
        "transitions": len(ctl.transitions),
        "measured_j": cluster.measured_energy_j(),
        "decode_mb": dec.decode_bytes / 1e6,
        "peak_occ": cluster.decode_pool.peak_occupancy,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="paged decode cache: continuous batching over a "
                         "block allocator, byte-accurate decode joules")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged cache budget in blocks (default: dense-equivalent)")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    full = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = make_prompts(cfg, args.requests, 8, 32)

    base_e = None
    for mode in ("default", "cap", "lock"):
        out = run_mode(mode, cfg, full, params, prompts, args)
        if base_e is None:
            base_e = out["energy_j"]
        save = 100 * (1 - out["energy_j"] / base_e)
        paged_note = (
            f" {out['decode_mb']:.2f}MB moved, peak_occ={out['peak_occ']},"
            if args.paged else ""
        )
        print(
            f"[{mode:8s}] prefill={out['prefill_clock']:5.0f}MHz "
            f"decode={out['decode_clock']:5.0f}MHz "
            f"decode_lever_engaged={str(out['decode_engaged']):5s} "
            f"E={out['energy_j']:8.2f}J savings={save:5.1f}% "
            f"({out['completed']} reqs, {out['decode_tokens']} decode tok,"
            f"{paged_note} {out['transitions']} lever transitions)"
        )
    print("\nconclusion: the cap changes nothing on decode; the per-pool lock"
          " banks the savings — the paper's Fig 3, live on the cluster.")


if __name__ == "__main__":
    main()
