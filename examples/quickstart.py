"""Quickstart: the three layers of the framework in one script.

1. Pick an architecture config (--arch, default gemma-2b, reduced for CPU).
2. Train it for a handful of steps (WSD schedule, checkpointing).
3. Serve a few requests through the continuous-batching engine.
4. Ask the energy layer the paper's question: what does a power cap do to
   this model's decode, and what clock should the decode pool lock?

Run:  PYTHONPATH=src python examples/quickstart.py [--arch minicpm-2b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (
    ClockLock,
    Default,
    EnergyModel,
    PowerCap,
    best_clock,
    classify_arch,
    decode_workload,
    resolve,
)
from repro.hw import TPU_V5E
from repro.launch.train import run_training
from repro.models import init_params
from repro.serving import ServingEngine
from repro.training import make_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    args = ap.parse_args()

    print(f"=== 1. config: {args.arch} (reduced for CPU) ===")
    cfg = reduced_config(args.arch)
    full = get_config(args.arch)
    print(f"full config: {full.param_count()/1e9:.2f}B params, {full.n_blocks} blocks")

    print("\n=== 2. train a few steps ===")
    report = run_training(arch=args.arch, steps=20, batch_size=4, seq_len=64, log_every=5)
    print(f"loss {report['first_loss']:.3f} -> {report['last_loss']:.3f}")

    print("\n=== 3. serve batched requests ===")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=4, max_seq_len=128)
    for p in make_prompts(cfg, 6, 8, 24):
        engine.submit(p, max_new_tokens=12)
    done = engine.run_to_completion()
    s = engine.stats
    print(f"completed {len(done)} requests; prefill {s.prefill_tokens} tok "
          f"/ decode {s.decode_tokens} tok")

    print("\n=== 4. the paper's question, for this arch on TPU v5e ===")
    em = EnergyModel(TPU_V5E)
    w = decode_workload(full, 32, 4096, fused=True)
    base = resolve(em, w, Default())
    print(f"decode draws {base.power_w:.0f}W on a {TPU_V5E.tdp:.0f}W chip "
          f"(dominant: {base.profile.dominant})")
    for cap in TPU_V5E.power_cap_levels[:2]:
        op = resolve(em, w, PowerCap(cap))
        print(f"cap {cap:.0f}W -> engaged={op.engaged}, clock {op.actual_clock_mhz:.0f}MHz")
    choice = best_clock(em, w)
    lock = resolve(em, w, ClockLock(choice.clock_mhz))
    print(f"lock {choice.clock_mhz:.0f}MHz -> saves "
          f"{100*(1-lock.energy_per_token_mj/base.energy_per_token_mj):.1f}% energy "
          f"at {100*(1-lock.throughput/base.throughput):.2f}% throughput loss")
    print(f"DVFS class: {classify_arch(em, full)}")


if __name__ == "__main__":
    main()
