"""Train a MiniCPM-style model with the WSD schedule, fault tolerance, and
checkpoint/restart — the training-side example.

Default: ~25M-param model, 60 steps (CPU-friendly). --hundred-m trains a
~100M-param config for --steps steps (the full deliverable-scale run; on
a pod swap the mesh via repro.launch).

Run:  PYTHONPATH=src python examples/train_wsd.py [--hundred-m --steps 300]
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ModelConfig, StageSpec, init_params
from repro.training import (
    AdamW,
    DataConfig,
    PackedLMStream,
    PreemptionGuard,
    StepWatchdog,
    init_train_state,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
    wsd_schedule,
)


def small_minicpm(hundred_m: bool) -> ModelConfig:
    base = get_config("minicpm-2b")
    if hundred_m:
        return dataclasses.replace(
            base, name="minicpm-100m", d_model=512,
            stages=(StageSpec(unit=("attn",), n_units=8),),
            n_heads=8, n_kv_heads=8, head_dim=64, d_ff=1536,
            vocab_size=32768, param_dtype="float32", compute_dtype="float32",
        )
    return dataclasses.replace(
        base, name="minicpm-25m", d_model=256,
        stages=(StageSpec(unit=("attn",), n_units=4),),
        n_heads=4, n_kv_heads=4, head_dim=64, d_ff=768,
        vocab_size=16384, param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = small_minicpm(args.hundred_m)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), WSD schedule")

    opt = AdamW()
    sched = wsd_schedule(
        6e-4, warmup_steps=max(args.steps // 10, 1),
        stable_steps=int(args.steps * 0.7), decay_steps=max(args.steps // 5, 1),
    )
    step = jax.jit(make_train_step(cfg, opt, sched, remat=True), donate_argnums=(0,))

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), f"wsd_{cfg.name}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params, opt)
    start = 0
    last = latest_step(ckpt_dir)
    if last:
        state = restore_checkpoint(ckpt_dir, last, jax.eval_shape(lambda: state))
        start = last
        print(f"resumed from checkpoint step {last}")

    data = PackedLMStream(cfg, DataConfig(seq_len=args.seq_len, batch_size=args.batch_size))
    guard = PreemptionGuard(install=True)
    wd = StepWatchdog(stall_factor=10.0, min_stall_s=300.0)
    wd.start()
    try:
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            state, m = step(state, batch)
            wd.beat()
            if (i + 1) % 10 == 0:
                print(f"step {i+1:4d} loss {float(m['loss']):.4f} lr {float(m['lr']):.2e}")
            if (i + 1) % 25 == 0 or guard.should_stop:
                save_checkpoint(ckpt_dir, i + 1, state)
            if guard.should_stop:
                print("preempted: final checkpoint written, exiting cleanly")
                return
    finally:
        wd.stop()
    save_checkpoint(ckpt_dir, args.steps, state)
    print(f"done; checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
