"""Reproduce the paper's headline characterisation in one command.

Prints: Table-1 analogue, the DVFS class table, the lock-vs-cap verdict,
the six hypotheses, and the MLA/recurrent crossovers — all from the
H200-calibrated energy model (see tests/test_paper_fidelity.py for the
acceptance bands backing every number).

Run:  PYTHONPATH=src python examples/characterize_paper.py
"""
from repro.configs.paper_models import PAPER_MODELS, PARADIGM
from repro.core import (
    ClockLock,
    Default,
    EnergyModel,
    PowerCap,
    classify_arch,
    crossover_output_length,
    decode_workload,
    evaluate_hypotheses,
    lock_dominates_caps,
    resolve,
    sweep_levers,
)
from repro.hw import H200_SXM


def main():
    model = EnergyModel(H200_SXM)
    cfgs = {k: v() for k, v in PAPER_MODELS.items()}

    print("== decode power vs caps (BS=1, seq=1024) ==")
    for name, cfg in cfgs.items():
        w = decode_workload(cfg, 1, 1024)
        base = resolve(model, w, Default())
        engaged = any(resolve(model, w, PowerCap(c)).engaged for c in H200_SXM.power_cap_levels)
        lock = resolve(model, w, ClockLock(780.0))
        print(f"{PARADIGM[name]:9s} {base.power_w:6.1f}W @ {base.actual_clock_mhz:.0f}MHz | "
              f"caps engage: {engaged} | lock@780: -{base.power_w - lock.power_w:5.1f}W "
              f"({100*(1-lock.energy_per_token_mj/base.energy_per_token_mj):.0f}% energy, "
              f"{100*(1-lock.throughput/base.throughput):.2f}% tput loss) | "
              f"class: {classify_arch(model, cfg)}")

    print("\n== lock vs cap Pareto ==")
    ok = all(
        lock_dominates_caps(*sweep_levers(model, decode_workload(cfg, b, 1024)))
        for cfg in cfgs.values() for b in (1, 32)
    )
    print(f"clock locking Pareto-dominates power capping in all tested configs: {ok}")

    print("\n== hypotheses ==")
    for h in evaluate_hypotheses(model, cfgs, gqa_ctrl="minitron-4b",
                                 mla="minitron-4b-mla", recurrent="mamba2-4b"):
        print(f"{h.hid} [{h.verdict:9s}] {h.statement}")

    print("\n== crossovers (prompt 4096, BS=32) ==")
    for chal, base_, label in (
        ("mamba2-4b", "qwen3-4b", "Mamba2 vs GQA"),
        ("gdn-4b", "qwen3-4b", "GDN vs GQA"),
        ("minitron-4b-mla", "minitron-4b", "MLA vs GQA-ctrl"),
    ):
        c = crossover_output_length(model, cfgs[chal], cfgs[base_],
                                    prompt_len=4096, batch=32, max_output=16384)
        print(f"{label}: total request energy crosses at ~{c} output tokens")


if __name__ == "__main__":
    main()
